//! Experiment metrics (§VI-A5): accuracy, Effective Update Ratio, bias,
//! durations, cost — recorded per round and summarized per experiment,
//! with CSV/JSON writers for the table/figure regeneration harness.

use std::collections::HashMap;
use std::path::Path;

use crate::util::Json;
use crate::{ClientId, Result};

/// Per-round record. Times are virtual-clock seconds.
#[derive(Debug, Clone)]
pub struct RoundRecord {
    pub round: u32,
    pub selected: Vec<ClientId>,
    /// On-time successes this round.
    pub successes: usize,
    /// Invoked but missed (slow or crashed).
    pub failures: usize,
    /// Stale updates folded into this round's aggregation (FedLesScan).
    pub stale_applied: usize,
    /// Selected clients skipped because their previous invocation was
    /// still in flight (the scheduler never re-invokes mid-flight).
    pub in_flight_skipped: usize,
    /// Round duration: slowest on-time client or the round timeout.
    pub duration_s: f64,
    /// Central accuracy after this round's aggregation (if evaluated).
    pub accuracy: Option<f32>,
    pub eval_loss: Option<f32>,
    /// Mean client training loss over on-time updates.
    pub train_loss: Option<f32>,
    /// Cost incurred this round ($).
    pub cost: f64,
    /// Effective Update Ratio of this round (successes / invoked; the
    /// in-flight-skipped clients are not in the denominator because they
    /// were never invoked).
    pub eur: f64,
    /// Wall-clock seconds spent in this round's client selection
    /// (tier partitioning, behaviour clustering, cohort sampling) —
    /// real machine time, not virtual time, excluded from the
    /// determinism goldens. The fleet-scale acceptance metric: it must
    /// stay sub-second at 100k+ clients.
    pub select_wall_s: f64,
    /// Wall-clock seconds spent in this round's aggregation fold (real
    /// machine time, not virtual time — excluded from the determinism
    /// goldens).
    pub agg_wall_s: f64,
    /// Peak live parameter-plane bytes during this round: model-weight
    /// buffers only (global snapshot, per-update vectors, staleness
    /// buffer, and the aggregation fold's real holdings — O(P) for the
    /// native streaming accumulator, O(k × P) for a buffered batch
    /// fold), tracked by [`crate::params::PlaneGauge`].
    pub param_plane_peak_bytes: usize,
}

impl RoundRecord {
    /// Effective Update Ratio. A round that invoked nobody delivered no
    /// effective updates, so its EUR is 0 — not the vacuous 1.0 the seed
    /// reported, which inflated mean EUR whenever `adaptive_clients`
    /// clamping or a strategy produced an empty selection.
    pub fn compute_eur(successes: usize, invoked: usize) -> f64 {
        if invoked == 0 {
            return 0.0;
        }
        successes as f64 / invoked as f64
    }
}

/// Full experiment result: the §VI metrics plus the raw timeline.
#[derive(Debug, Clone)]
pub struct ExperimentResult {
    /// Identification
    pub dataset: String,
    pub strategy: String,
    pub scenario: String,
    pub seed: u64,
    /// Timeline
    pub rounds: Vec<RoundRecord>,
    /// client -> number of invocations across the experiment (Fig. 3c).
    pub invocations: HashMap<ClientId, u32>,
    /// Totals
    pub total_time_s: f64,
    pub total_cost: f64,
    pub final_accuracy: f32,
}

impl ExperimentResult {
    /// Mean EUR across rounds (Table II columns).
    pub fn mean_eur(&self) -> f64 {
        if self.rounds.is_empty() {
            return 1.0;
        }
        self.rounds.iter().map(|r| r.eur).sum::<f64>() / self.rounds.len() as f64
    }

    /// Bias (§VI-A5, from SAFA [26]): difference between the most- and
    /// least-invoked client's invocation counts, over all registered
    /// clients (clients never invoked count as 0).
    pub fn bias(&self, n_clients: usize) -> u32 {
        let max = self.invocations.values().copied().max().unwrap_or(0);
        let min = if self.invocations.len() < n_clients {
            0
        } else {
            self.invocations.values().copied().min().unwrap_or(0)
        };
        max - min
    }

    /// First round at which accuracy crossed `target`, if ever (Fig. 3a
    /// convergence-speed comparisons).
    pub fn rounds_to_accuracy(&self, target: f32) -> Option<u32> {
        self.rounds
            .iter()
            .find(|r| r.accuracy.map_or(false, |a| a >= target))
            .map(|r| r.round)
    }

    /// Invocation count distribution (the Fig. 3c violin input).
    pub fn invocation_distribution(&self, n_clients: usize) -> Vec<u32> {
        (0..n_clients)
            .map(|c| self.invocations.get(&c).copied().unwrap_or(0))
            .collect()
    }

    /// Write the per-round timeline as CSV (Fig. 3a/3b series).
    pub fn write_timeline_csv(&self, path: &Path) -> Result<()> {
        let mut out = String::from(
            "round,selected,successes,failures,stale_applied,in_flight_skipped,duration_s,accuracy,eval_loss,train_loss,cost,eur,select_wall_s,agg_wall_s,param_plane_peak_bytes\n",
        );
        for r in &self.rounds {
            out.push_str(&format!(
                "{},{},{},{},{},{},{:.3},{},{},{},{:.6},{:.4},{:.6},{:.6},{}\n",
                r.round,
                r.selected.len(),
                r.successes,
                r.failures,
                r.stale_applied,
                r.in_flight_skipped,
                r.duration_s,
                r.accuracy.map_or(String::new(), |v| format!("{v:.4}")),
                r.eval_loss.map_or(String::new(), |v| format!("{v:.4}")),
                r.train_loss.map_or(String::new(), |v| format!("{v:.4}")),
                r.cost,
                r.eur,
                r.select_wall_s,
                r.agg_wall_s,
                r.param_plane_peak_bytes,
            ));
        }
        std::fs::write(path, out)?;
        Ok(())
    }

    /// Serialize the full result (rounds + invocation counts) to JSON.
    pub fn to_json(&self) -> Json {
        let rounds: Vec<Json> = self
            .rounds
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("round", Json::num(r.round as f64)),
                    (
                        "selected",
                        Json::Arr(r.selected.iter().map(|&c| Json::num(c as f64)).collect()),
                    ),
                    ("successes", Json::num(r.successes as f64)),
                    ("failures", Json::num(r.failures as f64)),
                    ("stale_applied", Json::num(r.stale_applied as f64)),
                    ("in_flight_skipped", Json::num(r.in_flight_skipped as f64)),
                    ("duration_s", Json::num(r.duration_s)),
                    (
                        "accuracy",
                        r.accuracy.map_or(Json::Null, |v| Json::num(v as f64)),
                    ),
                    (
                        "eval_loss",
                        r.eval_loss.map_or(Json::Null, |v| Json::num(v as f64)),
                    ),
                    (
                        "train_loss",
                        r.train_loss.map_or(Json::Null, |v| Json::num(v as f64)),
                    ),
                    ("cost", Json::num(r.cost)),
                    ("eur", Json::num(r.eur)),
                    ("select_wall_s", Json::num(r.select_wall_s)),
                    ("agg_wall_s", Json::num(r.agg_wall_s)),
                    (
                        "param_plane_peak_bytes",
                        Json::num(r.param_plane_peak_bytes as f64),
                    ),
                ])
            })
            .collect();
        let mut invocations: Vec<(ClientId, u32)> =
            self.invocations.iter().map(|(&c, &n)| (c, n)).collect();
        invocations.sort_unstable();
        Json::obj(vec![
            ("dataset", Json::str(self.dataset.clone())),
            ("strategy", Json::str(self.strategy.clone())),
            ("scenario", Json::str(self.scenario.clone())),
            ("seed", Json::num(self.seed as f64)),
            ("total_time_s", Json::num(self.total_time_s)),
            ("total_cost", Json::num(self.total_cost)),
            ("final_accuracy", Json::num(self.final_accuracy as f64)),
            ("mean_eur", Json::num(self.mean_eur())),
            ("rounds", Json::Arr(rounds)),
            (
                "invocations",
                Json::Arr(
                    invocations
                        .iter()
                        .map(|&(c, n)| {
                            Json::arr(vec![Json::num(c as f64), Json::num(n as f64)])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn write_json(&self, path: &Path) -> Result<()> {
        self.to_json().write_file(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: u32, succ: usize, sel: usize) -> RoundRecord {
        RoundRecord {
            round,
            selected: (0..sel).collect(),
            successes: succ,
            failures: sel - succ,
            stale_applied: 0,
            in_flight_skipped: 0,
            duration_s: 10.0,
            accuracy: Some(0.1 * round as f32),
            eval_loss: None,
            train_loss: None,
            cost: 0.01,
            eur: RoundRecord::compute_eur(succ, sel),
            select_wall_s: 0.0,
            agg_wall_s: 0.0,
            param_plane_peak_bytes: 0,
        }
    }

    fn exp(rounds: Vec<RoundRecord>) -> ExperimentResult {
        ExperimentResult {
            dataset: "mnist".into(),
            strategy: "fedavg".into(),
            scenario: "standard".into(),
            seed: 0,
            rounds,
            invocations: HashMap::new(),
            total_time_s: 0.0,
            total_cost: 0.0,
            final_accuracy: 0.0,
        }
    }

    #[test]
    fn eur_bounds() {
        assert_eq!(RoundRecord::compute_eur(0, 10), 0.0);
        assert_eq!(RoundRecord::compute_eur(10, 10), 1.0);
        // empty-round semantics: no invocations -> no effective updates
        assert_eq!(RoundRecord::compute_eur(0, 0), 0.0);
    }

    #[test]
    fn mean_eur_averages() {
        let e = exp(vec![rec(0, 5, 10), rec(1, 10, 10)]);
        assert!((e.mean_eur() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn bias_counts_uninvoked_clients_as_zero() {
        let mut e = exp(vec![]);
        e.invocations.insert(0, 5);
        e.invocations.insert(1, 3);
        // 4 registered clients, two never invoked -> min = 0
        assert_eq!(e.bias(4), 5);
        // only the two invoked registered -> min = 3
        assert_eq!(e.bias(2), 2);
    }

    #[test]
    fn rounds_to_accuracy_finds_crossing() {
        let e = exp(vec![rec(0, 1, 1), rec(1, 1, 1), rec(2, 1, 1)]);
        assert_eq!(e.rounds_to_accuracy(0.15), Some(2));
        assert_eq!(e.rounds_to_accuracy(0.9), None);
    }

    #[test]
    fn timeline_csv_has_header_and_rows() {
        let e = exp(vec![rec(0, 1, 2)]);
        let p = std::env::temp_dir().join(format!("fedless-tl-{}.csv", std::process::id()));
        e.write_timeline_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.starts_with("round,"));
        assert!(s
            .lines()
            .next()
            .unwrap()
            .ends_with("select_wall_s,agg_wall_s,param_plane_peak_bytes"));
        assert_eq!(s.lines().count(), 2);
        std::fs::remove_file(&p).ok();
    }
}
