//! Straggler sweep: the paper's core claim in one program. Runs every
//! evaluated strategy across straggler ratios on one dataset and prints
//! a compact comparison (accuracy / EUR / time / cost), i.e. a single-
//! dataset slice of Tables II-IV. (The full strategy x scenario grid —
//! storms, diurnal waves, outages, the adversarial tail — lives in
//! `fedless repro sweep`.)
//!
//!   cargo run --release --example straggler_sweep -- [dataset] [rounds]

use fedless::config::{ExperimentConfig, Scenario};
use fedless::coordinator::Controller;
use fedless::runtime::{load_backend, BackendKind};
use fedless::strategy::StrategyKind;

fn main() -> fedless::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dataset = args.first().map(String::as_str).unwrap_or("speech").to_string();
    let rounds: u32 = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(8);

    let backend = load_backend(BackendKind::Native, "artifacts".as_ref(), &dataset)?;

    println!(
        "straggler sweep on {dataset} ({rounds} rounds/cell)\n{:<12} {:<12} {:>9} {:>9} {:>11} {:>10} {:>6}",
        "scenario", "strategy", "accuracy", "mean EUR", "time (min)", "cost ($)", "bias"
    );
    for pct in [0u8, 10, 30, 50, 70] {
        let scenario = if pct == 0 {
            Scenario::Standard
        } else {
            Scenario::Straggler(pct)
        };
        for strategy in StrategyKind::evaluated() {
            let mut cfg = ExperimentConfig::preset(&dataset);
            cfg.strategy = strategy;
            cfg.scenario = scenario;
            cfg.rounds = rounds;
            cfg.n_clients = (cfg.n_clients / 2).max(12);
            cfg.clients_per_round = (cfg.clients_per_round / 2).max(4);
            let n = cfg.n_clients;
            let mut ctl = Controller::new(cfg, backend.as_ref())?;
            let r = ctl.run()?;
            println!(
                "{:<12} {:<12} {:>9.3} {:>9.3} {:>11.1} {:>10.4} {:>6}",
                scenario.label(),
                strategy.as_str(),
                r.final_accuracy,
                r.mean_eur(),
                r.total_time_s / 60.0,
                r.total_cost,
                r.bias(n)
            );
        }
    }
    Ok(())
}
