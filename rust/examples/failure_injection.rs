//! Failure injection: stress the platform model (high transient failure
//! rate, aggressive scale-to-zero, heavy VM heterogeneity) and watch the
//! client-history DB drive the three-tier partitioning — a direct window
//! into Eq. 1 cooldown dynamics and Algorithm 2 tiering.
//!
//!   cargo run --release --example failure_injection

use fedless::config::{ExperimentConfig, Scenario};
use fedless::coordinator::Controller;
use fedless::runtime::{load_backend, BackendKind};
use fedless::strategy::StrategyKind;

fn main() -> fedless::Result<()> {
    let backend = load_backend(BackendKind::Native, "artifacts".as_ref(), "mnist")?;

    let mut cfg = ExperimentConfig::preset("mnist");
    cfg.strategy = StrategyKind::Fedlesscan;
    cfg.scenario = Scenario::Standard;
    cfg.rounds = 10;
    cfg.n_clients = 20;
    cfg.clients_per_round = 8;
    // hostile platform: 15% dropped invocations, fast scale-to-zero
    // (every round starts cold), very heterogeneous VMs
    cfg.faas.transient_failure_rate = 0.15;
    cfg.faas.idle_timeout_s = 10.0;
    cfg.faas.client_speed_sigma = 0.6;
    cfg.history_path = Some("results/failure_injection_history.json".into());
    std::fs::create_dir_all("results")?;

    let mut ctl = Controller::new(cfg, backend.as_ref())?;
    let result = ctl.run()?;

    println!("== per-round failures under a hostile platform ==");
    println!(
        "{:>5} {:>9} {:>9} {:>7} {:>8} {:>9}",
        "round", "selected", "failures", "EUR", "stale", "in-flight"
    );
    for r in &result.rounds {
        println!(
            "{:>5} {:>9} {:>9} {:>7.2} {:>8} {:>9}",
            r.round,
            r.selected.len(),
            r.failures,
            r.eur,
            r.stale_applied,
            r.in_flight_skipped
        );
    }

    println!("\n== client history after the run (Eq. 1 state) ==");
    println!(
        "{:>6} {:>6} {:>9} {:>9} {:>9} {:>14}",
        "client", "invoc", "success", "missed", "cooldown", "mean train (s)"
    );
    let hist = ctl.history();
    let mut ids: Vec<_> = hist.iter().map(|(&c, _)| c).collect();
    ids.sort_unstable();
    for c in ids {
        let h = hist.view(c);
        println!(
            "{:>6} {:>6} {:>9} {:>9} {:>9} {:>14.1}",
            c,
            h.invocations,
            h.successes,
            h.missed_total(),
            h.cooldown,
            h.training_mean()
        );
    }
    println!(
        "\nhistory snapshot saved to results/failure_injection_history.json; \
         mean EUR {:.3}, final acc {:.3}",
        result.mean_eur(),
        result.final_accuracy
    );
    Ok(())
}
