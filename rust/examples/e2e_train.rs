//! End-to-end driver (DESIGN.md §4 E2E): federated training of the
//! char-level token model through the full stack — per-client local
//! rounds on the execution backend, driven by the Rust coordinator over
//! the simulated serverless platform, with FedLesScan selection and
//! staleness-aware aggregation — for a few hundred rounds, logging the
//! loss curve.
//!
//!   cargo run --release --example e2e_train -- \
//!       [--rounds 120] [--clients 24] [--per-round 8] [--stragglers 30] \
//!       [--out results/e2e]
//!
//! The loss curve lands in `<out>/e2e_loss.csv` and the full timeline in
//! `<out>/e2e.json`; EXPERIMENTS.md records a checked-in run.

use std::path::PathBuf;

use fedless::config::{ExperimentConfig, Scenario};
use fedless::coordinator::Controller;
use fedless::runtime::{load_backend, BackendKind};
use fedless::strategy::StrategyKind;
use fedless::util::cli;

fn main() -> fedless::Result<()> {
    let args = cli::parse(std::env::args().skip(1), &["verbose"])?;
    let rounds: u32 = args.get_parse("rounds", 120)?;
    let stragglers: u8 = args.get_parse("stragglers", 30)?;
    let out = PathBuf::from(args.get_str("out", "results/e2e"));

    let backend = load_backend(BackendKind::Native, "artifacts".as_ref(), "transformer")?;
    let mf = backend.manifest();
    println!(
        "e2e: char-transformer P={} (seq={}, vocab={}), {} rounds, {}% stragglers",
        mf.param_count,
        mf.seq_len.unwrap_or(0),
        mf.num_classes,
        rounds,
        stragglers
    );

    let mut cfg = ExperimentConfig::preset("transformer");
    cfg.strategy = StrategyKind::Fedlesscan;
    cfg.scenario = if stragglers == 0 {
        Scenario::Standard
    } else {
        Scenario::Straggler(stragglers)
    };
    cfg.rounds = rounds;
    cfg.n_clients = args.get_parse("clients", cfg.n_clients)?;
    cfg.clients_per_round = args.get_parse("per-round", cfg.clients_per_round)?;
    cfg.eval_every = 5;
    cfg.verbose = args.get_bool("verbose");

    let total_local_steps = rounds as usize * cfg.clients_per_round * mf.steps_per_round;
    println!(
        "≈ {total_local_steps} distributed optimizer steps ({} per client round)",
        mf.steps_per_round
    );

    let t0 = std::time::Instant::now();
    let mut ctl = Controller::new(cfg, backend.as_ref())?;
    let result = ctl.run()?;
    let wall = t0.elapsed();

    std::fs::create_dir_all(&out)?;
    // loss curve CSV: round, train loss, eval loss, accuracy
    let mut csv = String::from("round,train_loss,eval_loss,accuracy\n");
    for r in &result.rounds {
        csv.push_str(&format!(
            "{},{},{},{}\n",
            r.round,
            r.train_loss.map_or(String::new(), |v| format!("{v:.4}")),
            r.eval_loss.map_or(String::new(), |v| format!("{v:.4}")),
            r.accuracy.map_or(String::new(), |v| format!("{v:.4}")),
        ));
    }
    std::fs::write(out.join("e2e_loss.csv"), csv)?;
    result.write_json(&out.join("e2e.json"))?;

    let first_loss = result.rounds.iter().find_map(|r| r.train_loss);
    let last_loss = result.rounds.iter().rev().find_map(|r| r.train_loss);
    println!("\n== e2e summary ==");
    println!("wall time       : {wall:.1?}");
    println!(
        "train loss      : {:.3} -> {:.3}",
        first_loss.unwrap_or(f32::NAN),
        last_loss.unwrap_or(f32::NAN)
    );
    println!("final accuracy  : {:.3}", result.final_accuracy);
    println!("mean EUR        : {:.3}", result.mean_eur());
    println!("virtual time    : {:.1} min", result.total_time_s / 60.0);
    println!("simulated cost  : ${:.4}", result.total_cost);
    println!("wrote {}/e2e_loss.csv and e2e.json", out.display());
    Ok(())
}
