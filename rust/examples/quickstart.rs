//! Quickstart: train a federated MNIST-style model with FedLesScan on the
//! simulated serverless platform, then print the §VI metrics.
//!
//!   cargo run --release --example quickstart
//!
//! This is the smallest end-to-end use of the public API: build the
//! native execution backend, build a config from a preset, run the
//! controller. No artifacts or external libraries needed; a
//! `--features pjrt` build can swap in `BackendKind::Pjrt` for the AOT
//! HLO path.

use fedless::config::{ExperimentConfig, Scenario};
use fedless::coordinator::Controller;
use fedless::runtime::{load_backend, BackendKind};
use fedless::strategy::StrategyKind;

fn main() -> fedless::Result<()> {
    // 1. The execution backend for one model family.
    let backend = load_backend(BackendKind::Native, "artifacts".as_ref(), "mnist")?;
    println!(
        "loaded {} backend: {} (P={} params)",
        backend.backend_name(),
        backend.manifest().name,
        backend.manifest().param_count
    );

    // 2. Experiment config: the paper-preset deployment shape, shrunk a
    //    bit so the quickstart finishes in seconds.
    let mut cfg = ExperimentConfig::preset("mnist");
    cfg.strategy = StrategyKind::Fedlesscan;
    cfg.scenario = Scenario::Straggler(30); // 30% forced stragglers
    cfg.rounds = 8;
    cfg.n_clients = 24;
    cfg.clients_per_round = 8;
    cfg.verbose = true; // per-round metrics on stderr

    // 3. Run the federated experiment.
    let n_clients = cfg.n_clients;
    let mut controller = Controller::new(cfg, backend.as_ref())?;
    let result = controller.run()?;

    // 4. Report the paper's metrics (§VI-A5).
    println!("\n== results ==");
    println!("final accuracy : {:.3}", result.final_accuracy);
    println!("mean EUR       : {:.3}", result.mean_eur());
    println!("total time     : {:.1} virtual min", result.total_time_s / 60.0);
    println!("total cost     : ${:.4}", result.total_cost);
    println!("bias           : {}", result.bias(n_clients));
    if let Some(r) = result.rounds_to_accuracy(0.5) {
        println!("rounds to 50%  : {r}");
    }
    Ok(())
}
