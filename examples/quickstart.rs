//! Quickstart: train a federated MNIST-style model with FedLesScan on the
//! simulated serverless platform, then print the §VI metrics.
//!
//!   make artifacts && cargo run --release --example quickstart
//!
//! This is the smallest end-to-end use of the public API: load an AOT
//! artifact set, build a config from a preset, run the controller.

use fedless::config::{ExperimentConfig, Scenario};
use fedless::coordinator::Controller;
use fedless::runtime::{Engine, ModelRuntime};
use fedless::strategy::StrategyKind;

fn main() -> fedless::Result<()> {
    // 1. PJRT CPU engine + the compiled artifact set for one model family.
    let engine = Engine::cpu()?;
    let runtime = ModelRuntime::load(&engine, "artifacts".as_ref(), "mnist")?;
    println!(
        "loaded {} (P={} params, compiled in {:.2?})",
        runtime.manifest.name, runtime.manifest.param_count, runtime.compile_time
    );

    // 2. Experiment config: the paper-preset deployment shape, shrunk a
    //    bit so the quickstart finishes in ~1 minute.
    let mut cfg = ExperimentConfig::preset("mnist");
    cfg.strategy = StrategyKind::Fedlesscan;
    cfg.scenario = Scenario::Straggler(30); // 30% forced stragglers
    cfg.rounds = 8;
    cfg.n_clients = 24;
    cfg.clients_per_round = 8;
    cfg.verbose = true;

    // 3. Run the federated experiment.
    let n_clients = cfg.n_clients;
    let mut controller = Controller::new(cfg, &runtime)?;
    let result = controller.run()?;

    // 4. Report the paper's metrics (§VI-A5).
    println!("\n== results ==");
    println!("final accuracy : {:.3}", result.final_accuracy);
    println!("mean EUR       : {:.3}", result.mean_eur());
    println!("total time     : {:.1} virtual min", result.total_time_s / 60.0);
    println!("total cost     : ${:.4}", result.total_cost);
    println!("bias           : {}", result.bias(n_clients));
    if let Some(r) = result.rounds_to_accuracy(0.5) {
        println!("rounds to 50%  : {r}");
    }
    Ok(())
}
